"""Event-horizon streaming simulator: SimConfig API, ArrivalSource
protocol, horizon≡per-event bit-identity, and streaming accumulators."""
import warnings

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.sim import (
    ArrivalSource,
    ChunkSource,
    GridSim,
    JobList,
    P2PGridSim,
    SimConfig,
    SimJob,
    StreamingQuantiles,
    bulk_burst,
    cms_case_study,
    paper_grid_spec,
    poisson_source,
    poisson_stream,
    serving_trace_source,
)
from repro.sim.streaming import as_arrival_source

NODES = paper_grid_spec()
QUOTAS = {"hog": 10.0, "polite": 1000.0}


def _overload_jobs(seed=9):
    """Migration-heavy reference: a hog flood plus polite traffic."""
    jobs = list(bulk_burst("hog", 60, at=0.0, work=400.0,
                           data_site="site1", origin_site="site1"))
    jobs += list(bulk_burst("polite", 20, at=5.0, work=100.0,
                            data_site="site2", origin_site="site2"))
    jobs += list(poisson_stream("polite", 0.2, 400.0, seed=seed, work=120.0))
    return jobs


def _placements(result):
    return [(j.user, j.arrival, j.exec_site, j.start, j.finish, j.migrated)
            for j in result.jobs]


def _grid(horizon, policy="diana", **kw):
    cfg = SimConfig(policy=policy, quotas=QUOTAS, migration_interval_s=30.0,
                    congestion_window_s=120.0, horizon=horizon, **kw)
    return GridSim(NODES, config=cfg)


# -- SimConfig API ----------------------------------------------------------

class TestSimConfig:
    def test_legacy_kwargs_warn_once_and_match_config(self):
        import repro.sim.config as config_mod
        config_mod._warned_legacy = False
        with pytest.warns(DeprecationWarning, match="deprecated"):
            a = GridSim(NODES, policy="greedy", bucket_s=30.0)
        with warnings.catch_warnings():
            warnings.simplefilter("error")           # second use: silent
            b = GridSim(NODES, policy="greedy", bucket_s=30.0)
        c = GridSim(NODES, config=SimConfig(policy="greedy", bucket_s=30.0))
        assert a.config == b.config == c.config

    def test_unknown_kwarg_raises(self):
        with pytest.raises(TypeError, match="unexpected"):
            GridSim(NODES, polcy="diana")

    def test_p2p_kwarg_rejected_on_base_sim(self):
        with pytest.raises(TypeError):
            GridSim(NODES, num_peers=3)

    def test_p2p_validates_policy_and_interval(self):
        with pytest.raises(ValueError):
            P2PGridSim(NODES, config=SimConfig(policy="greedy"))
        with pytest.raises(ValueError):
            P2PGridSim(NODES, config=SimConfig(exchange_interval_s=0.0))

    def test_replace(self):
        cfg = SimConfig().replace(policy="fcfs", num_peers=5)
        assert cfg.policy == "fcfs" and cfg.num_peers == 5
        assert SimConfig().policy == "diana"          # original untouched

    def test_config_attribute_mirrors(self):
        sim = _grid(True)
        assert sim.policy == "diana"
        assert sim.migration_interval_s == 30.0
        assert sim.config.congestion_window_s == 120.0


# -- ArrivalSource protocol -------------------------------------------------

class TestArrivalSource:
    def test_generators_conform(self):
        assert isinstance(bulk_burst("u", 3), ArrivalSource)
        assert isinstance(poisson_stream("u", 1.0, 10.0), ArrivalSource)
        assert isinstance(poisson_source("u", 1.0, 10.0), ArrivalSource)
        assert isinstance(cms_case_study(scale=0.05), ArrivalSource)
        assert isinstance(JobList(), ArrivalSource)

    def test_as_arrival_source_sorts_plain_lists(self):
        jobs = [SimJob("u", arrival=5.0, work=1.0),
                SimJob("u", arrival=1.0, work=1.0)]
        src = as_arrival_source(jobs)
        chunk = next(iter(src.chunks()))
        assert [j.arrival for j in chunk] == [1.0, 5.0]
        assert jobs[0].arrival == 5.0                  # input list untouched

    def test_as_arrival_source_rejects_non_source(self):
        with pytest.raises(TypeError):
            as_arrival_source(object())

    def test_chunk_source_reiterable(self):
        src = poisson_source("u", 2.0, 50.0, seed=1, chunk_jobs=16)
        a = [j.arrival for c in src.chunks() for j in c]
        b = [j.arrival for c in src.chunks() for j in c]
        assert a == b and len(a) > 16

    def test_out_of_order_chunks_rejected(self):
        src = ChunkSource(lambda: iter([
            [SimJob("u", arrival=10.0, work=1.0)],
            [SimJob("u", arrival=1.0, work=1.0)],
        ]))
        with pytest.raises(ValueError, match="non-decreasing"):
            GridSim(NODES, config=SimConfig()).run(src)

    def test_run_list_equals_run_source(self):
        jobs = _overload_jobs()
        ra = _grid(True).run(list(jobs))
        rb = _grid(True, retain_jobs=True).run(as_arrival_source(list(jobs)))
        # run(list) echoes the caller's order; the collected stream is in
        # admission order — same placements either way
        assert sorted(_placements(ra)) == sorted(_placements(rb))

    def test_poisson_source_equals_poisson_stream(self):
        a = poisson_stream("u", 1.5, 200.0, seed=7, work=30.0)
        b = [j for c in poisson_source("u", 1.5, 200.0, seed=7, work=30.0,
                                       chunk_jobs=13).chunks() for j in c]
        assert [(x.arrival, x.work) for x in a] == [(x.arrival, x.work) for x in b]


# -- horizon ≡ per-event equivalence ---------------------------------------

class TestHorizonEquivalence:
    @pytest.mark.parametrize("policy", ["diana", "greedy", "local", "fcfs"])
    def test_gridsim_bit_identical(self, policy):
        jobs = _overload_jobs()
        ra = _grid(False, policy).run(list(jobs))
        rb = _grid(True, policy).run(list(jobs))
        assert _placements(ra) == _placements(rb)
        assert ra.makespan == rb.makespan

    def test_gridsim_bit_identical_cms(self):
        jobs = cms_case_study(scale=0.3, seed=4)
        ra = _grid(False).run(list(jobs))
        rb = _grid(True).run(list(jobs))
        assert _placements(ra) == _placements(rb)

    @pytest.mark.parametrize("latency", [0.0, 5.0])
    def test_p2p_bit_identical(self, latency):
        def run(hz):
            cfg = SimConfig(quotas=QUOTAS, migration_interval_s=30.0,
                            congestion_window_s=120.0, num_peers=3,
                            exchange_interval_s=45.0,
                            exchange_latency_s=latency, horizon=hz)
            return P2PGridSim(NODES, config=cfg).run(_overload_jobs())
        assert _placements(run(False)) == _placements(run(True))

    def test_p2p_bit_identical_gossip_heavy(self):
        """Frequent gossip + delta wire + fanout cap + quantization."""
        def run(hz):
            cfg = SimConfig(quotas=QUOTAS, migration_interval_s=20.0,
                            congestion_window_s=60.0, num_peers=5,
                            exchange_interval_s=10.0, exchange_latency_s=2.0,
                            gossip_fanout=2, gossip_wire="delta",
                            gossip_quant="f16", gossip_full_sync_every=4,
                            horizon=hz)
            return P2PGridSim(NODES, config=cfg).run(_overload_jobs())
        assert _placements(run(False)) == _placements(run(True))

    def test_eps_window_batches_more_but_completes(self):
        """eps>0 is a documented approximation — not bit-identical, but
        every job must still complete."""
        jobs = poisson_stream("u", 2.0, 100.0, seed=2, work=20.0)
        r = _grid(True, horizon_eps_s=5.0).run(list(jobs))
        assert all(j.finish >= 0 for j in r.jobs)
        assert r.stats.finished == len(jobs)

    @settings(max_examples=10, deadline=None)
    @given(chunk=st.integers(min_value=1, max_value=97))
    def test_chunking_invariance(self, chunk):
        """Property: how the source chunks its stream must not change a
        single placement."""
        base = poisson_stream("u", 1.0, 120.0, seed=11, work=40.0)
        jobs = sorted(base, key=lambda j: j.arrival)

        def chunked():
            for i in range(0, len(jobs), chunk):
                yield [SimJob(user=j.user, arrival=j.arrival, work=j.work,
                              input_bytes=j.input_bytes, output_bytes=j.output_bytes,
                              data_site=j.data_site, origin_site=j.origin_site,
                              group_id=j.group_id)
                       for j in jobs[i:i + chunk]]

        ra = _grid(True).run(list(base))
        rb = _grid(True, retain_jobs=True).run(ChunkSource(chunked))
        assert _placements(ra) == _placements(rb)


# -- streaming accumulators -------------------------------------------------

class TestStreamStats:
    def test_counts_and_peak_in_flight(self):
        jobs = poisson_stream("u", 1.0, 300.0, seed=5, work=90.0)
        r = _grid(True).run(list(jobs))
        s = r.stats
        assert s.admitted == s.finished == len(jobs)
        assert 1 <= s.peak_in_flight <= len(jobs)
        assert s.last_finish == r.makespan

    def test_streaming_mode_retains_no_jobs_by_default(self):
        src = poisson_source("u", 1.0, 300.0, seed=5, work=90.0)
        r = _grid(True).run(src)
        assert r.jobs == []
        assert r.stats.admitted == r.stats.finished > 0
        assert r.throughput > 0 and r.avg_turnaround > 0

    def test_retain_jobs_collects_stream(self):
        src = poisson_source("u", 1.0, 300.0, seed=5, work=90.0)
        r = _grid(True, retain_jobs=True).run(src)
        assert len(r.jobs) == r.stats.admitted > 0

    def test_stream_stats_match_materialized_run(self):
        jobs = poisson_stream("u", 1.0, 300.0, seed=6, work=90.0)
        r_list = _grid(True).run(list(jobs))
        r_src = _grid(True).run(poisson_source("u", 1.0, 300.0, seed=6, work=90.0))
        assert r_list.stats == r_src.stats

    def test_percentiles_close_to_exact(self):
        jobs = poisson_stream("u", 2.0, 500.0, seed=8, work=120.0)
        r = _grid(True).run(list(jobs))
        exact = np.quantile([j.turnaround for j in r.jobs], [0.5, 0.95, 0.99])
        approx = r.turnaround_percentiles()
        for e, a in zip(exact, approx):
            assert abs(a - e) <= 0.05 * max(e, 1e-9)
        # queue-time percentiles exist and are ordered
        q50, q95, q99 = r.queue_time_percentiles()
        assert q50 <= q95 <= q99

    def test_quantile_accumulator_accuracy(self):
        rng = np.random.default_rng(0)
        xs = rng.lognormal(mean=3.0, sigma=1.2, size=20000)
        acc = StreamingQuantiles()
        for x in xs:
            acc.add(float(x))
        for q in (0.5, 0.9, 0.95, 0.99):
            exact = float(np.quantile(xs, q))
            assert abs(acc.quantile(q) - exact) <= 0.03 * exact
        assert acc.n == len(xs)
        assert acc.vmin == xs.min() and acc.vmax == xs.max()

    def test_quantile_edge_cases(self):
        acc = StreamingQuantiles()
        assert acc.quantile(0.5) == 0.0               # empty
        acc.add(0.0)                                   # underflow bucket
        assert acc.quantile(0.5) == 0.0
        acc.add(1e12)                                  # overflow bucket
        assert acc.quantile(1.0) == 1e12


# -- serving-trace adapter --------------------------------------------------

class _FakeRequest:
    """Duck-typed InferenceRequest: the adapter must not need jax."""

    def __init__(self, user, plen, new, at, gid=None):
        self.user = user
        self.prompt = np.arange(plen, dtype=np.int32)
        self.max_new_tokens = new
        self.submit_time = at
        self.group_id = gid


class TestServingTraceSource:
    def test_trace_replays_through_grid(self):
        reqs = [_FakeRequest("tenantA", 8, 4, float(i)) for i in range(40)]
        reqs += [_FakeRequest("tenantB", 16, 8, float(i) + 0.5, gid="bulk1")
                 for i in range(40)]
        reqs.sort(key=lambda r: r.submit_time)
        src = serving_trace_source(reqs, work_per_token=0.5, chunk_jobs=8)
        r = GridSim(NODES, config=SimConfig(retain_jobs=True)).run(src)
        assert r.stats.admitted == 80 and r.stats.finished == 80
        by_user = {j.user for j in r.jobs}
        assert by_user == {"tenantA", "tenantB"}
        a = next(j for j in r.jobs if j.user == "tenantA")
        assert a.work == (8 + 4) * 0.5
        assert a.input_bytes == 8 * 4                  # int32 prompt bytes
        b = next(j for j in r.jobs if j.user == "tenantB")
        assert b.group_id == "bulk1"

    def test_origin_of_routes_tenants(self):
        reqs = [_FakeRequest("a", 4, 2, 0.0), _FakeRequest("b", 4, 2, 0.0)]
        src = serving_trace_source(
            reqs, origin_of=lambda r: "site2" if r.user == "b" else "site1")
        jobs = [j for c in src.chunks() for j in c]
        assert {j.origin_site for j in jobs} == {"site1", "site2"}
