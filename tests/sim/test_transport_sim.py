"""Transport faults inside the simulators: zero-rate bit-identity,
seeded-loss determinism, horizon≡per-event reproducibility, and the
suspicion-driven staleness widening."""
import pytest

from repro.sim import (
    P2PGridSim,
    PartitionWindow,
    SimConfig,
    TransportFaults,
    bulk_burst,
    paper_grid_spec,
    poisson_stream,
)

NODES = paper_grid_spec()
QUOTAS = {"hog": 10.0, "polite": 1000.0}


def _jobs(seed=9):
    jobs = list(bulk_burst("hog", 50, at=0.0, work=400.0,
                           data_site="site1", origin_site="site1"))
    jobs += list(poisson_stream("polite", 0.2, 400.0, seed=seed, work=120.0))
    return jobs


def _placements(result):
    return [(j.user, j.arrival, j.exec_site, j.start, j.finish, j.migrated)
            for j in result.jobs]


def _run(transport, wire="delta", horizon=False, **kw):
    cfg = SimConfig(policy="diana", quotas=QUOTAS, migration_interval_s=30.0,
                    congestion_window_s=120.0, num_peers=3,
                    exchange_interval_s=45.0, exchange_latency_s=5.0,
                    gossip_wire=wire, transport_faults=transport,
                    horizon=horizon, **kw)
    sim = P2PGridSim(NODES, config=cfg)
    return sim, sim.run(_jobs())


LOSSY = TransportFaults(seed=3, loss=0.15, duplicate=0.05,
                        reorder_jitter_s=8.0, corrupt=0.02)


@pytest.mark.parametrize("wire", ["delta", "full"])
def test_zero_rate_transport_is_bit_identical(wire):
    """ISSUE acceptance: attaching an all-zero TransportFaults changes
    nothing — same placements, same timeline, on either wire."""
    _, base = _run(None, wire=wire)
    sim, faulted = _run(TransportFaults(seed=42), wire=wire)
    assert _placements(base) == _placements(faulted)
    assert base.timeline == faulted.timeline
    assert sim.exchange.stats.dropped == 0
    assert sim.exchange.stats.retransmits == 0


@pytest.mark.parametrize("wire", ["delta", "full"])
def test_lossy_run_is_deterministic(wire):
    """Seeded faults replay bit-identically across fresh sims."""
    sa, ra = _run(LOSSY, wire=wire)
    sb, rb = _run(LOSSY, wire=wire)
    assert _placements(ra) == _placements(rb)
    assert sa.exchange.stats.as_dict() == sb.exchange.stats.as_dict()
    assert sa.exchange.stats.dropped > 0   # the model actually engaged


def test_lossy_horizon_equals_per_event():
    """The fault draws ride the exchange's own RNG, not wall-ordering,
    so the event-horizon loop replays the per-event loop exactly."""
    _, ra = _run(LOSSY, horizon=False)
    _, rb = _run(LOSSY, horizon=True)
    assert _placements(ra) == _placements(rb)


def test_rerun_on_same_sim_resets_transport():
    """run() re-seeds the transport RNG and drops in-flight state:
    two sims each rerun stay in lockstep, and nothing stays airborne
    across runs."""
    def twice():
        cfg = SimConfig(policy="diana", quotas=QUOTAS,
                        migration_interval_s=30.0, congestion_window_s=120.0,
                        num_peers=3, exchange_interval_s=45.0,
                        exchange_latency_s=5.0, transport_faults=LOSSY)
        sim = P2PGridSim(NODES, config=cfg)
        sim.run(_jobs())
        assert sim.exchange.in_flight == 0
        assert not sim.exchange._pending
        return sim, sim.run(_jobs())
    sa, ra = twice()
    sb, rb = twice()
    assert _placements(ra) == _placements(rb)
    assert sa.exchange.stats.as_dict() == sb.exchange.stats.as_dict()


def test_partitioned_run_completes_and_escalates():
    north = frozenset(n for i, n in enumerate(sorted(NODES)) if i % 2 == 0)
    south = frozenset(sorted(NODES)) - north
    t = TransportFaults(
        seed=1,
        partitions=(PartitionWindow(start=100.0, end=700.0,
                                    groups=(north, south)),),
    )
    sim, res = _run(t)
    assert all(j.finish >= 0 for j in res.jobs)
    assert sim.exchange.stats.dropped > 0
    assert sim.exchange.stats.sync_escalations > 0


def test_staleness_widening_property():
    """migration_max_staleness_s widens under suspicion and restores
    once the suspects clear; the setter keeps working."""
    sim, _ = _run(None)
    base = sim.migration_max_staleness_s
    sim._staleness_widen = 3.0
    assert sim.migration_max_staleness_s == 3.0 * base
    sim._staleness_widen = 1.0
    assert sim.migration_max_staleness_s == base
    sim.migration_max_staleness_s = 123.0   # tests assign it directly
    assert sim.migration_max_staleness_s == 123.0


def test_transport_faults_rejected_without_peers():
    """transport_faults is a P2P-only knob: the base single-scheduler
    sim has no gossip wire to fault."""
    from repro.sim import GridSim
    with pytest.raises(TypeError):
        GridSim(NODES, transport_faults=LOSSY)
