"""int8-moment AdamW: tracks f32 AdamW closely; 10× smaller state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.adamw8 import adamw8_init, adamw8_update


def _params(seed=0):
    k = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(k)
    return {"w": jax.random.normal(k1, (32, 48)),
            "b": jax.random.normal(k2, (48,)) * 0.1}


def test_tracks_f32_adam_over_steps():
    cfg = AdamWConfig(weight_decay=0.0)
    p32 = p8 = _params()
    o32 = adamw_init(p32)
    o8 = adamw8_init(p8)
    key = jax.random.PRNGKey(1)
    for t in range(20):
        key, sub = jax.random.split(key)
        g = jax.tree.map(
            lambda p: jax.random.normal(sub, p.shape) * 0.1 + 0.05 * p, p32)
        p32, o32 = adamw_update(g, o32, p32, 1e-2, cfg)
        p8, o8 = adamw8_update(g, o8, p8, 1e-2, cfg)
    for a, b in zip(jax.tree.leaves(p32), jax.tree.leaves(p8)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0.05, atol=0.02)


def test_descends_a_quadratic():
    target = jnp.asarray(np.linspace(-1, 1, 64).reshape(8, 8), jnp.float32)
    p = {"w": jnp.zeros((8, 8))}
    o = adamw8_init(p)
    cfg = AdamWConfig(weight_decay=0.0)
    losses = []
    for _ in range(150):
        g = {"w": 2 * (p["w"] - target)}
        losses.append(float(jnp.sum(jnp.square(p["w"] - target))))
        p, o = adamw8_update(g, o, p, 5e-2, cfg)
    assert losses[-1] < losses[0] * 0.05


def test_state_is_actually_int8():
    p = _params()
    o = adamw8_init(p)
    leaves = jax.tree.leaves(o["m"])
    qs = [l for l in leaves if l.dtype == jnp.int8]
    assert qs, "moments must be stored int8"
    f32_bytes = sum(l.size * 4 for l in jax.tree.leaves(adamw_init(p)["m"]))
    q_bytes = sum(l.size * l.dtype.itemsize for l in leaves)
    assert q_bytes < f32_bytes * 0.35
