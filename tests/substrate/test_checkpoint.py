"""Checkpoint: crash-safe commit, async writer, retention, elastic restore."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, restore_checkpoint, save_checkpoint


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32)),
        "nested": {"b": jnp.asarray(rng.normal(size=(16,)), jnp.bfloat16)},
        "step_count": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 10, tree)
    restored, step = restore_checkpoint(tmp_path, tree)
    assert step == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_committed_wins(tmp_path):
    save_checkpoint(tmp_path, 1, _tree(1))
    save_checkpoint(tmp_path, 5, _tree(5))
    _, step = restore_checkpoint(tmp_path, _tree())
    assert step == 5


def test_torn_checkpoint_ignored(tmp_path):
    save_checkpoint(tmp_path, 1, _tree(1))
    # fake a torn write: directory without COMMIT
    torn = tmp_path / "step_00000009"
    torn.mkdir()
    (torn / "arrays.npz").write_bytes(b"garbage")
    _, step = restore_checkpoint(tmp_path, _tree())
    assert step == 1


def test_async_manager_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _tree(s))
    mgr.wait()
    assert mgr.latest_step() == 4
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir()
                   if p.name.startswith("step_"))
    assert steps == [3, 4]  # keep=2


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(tmp_path / "nope", _tree())


def test_elastic_restore_with_shardings(tmp_path):
    """Restore re-places arrays under a different device layout."""
    tree = _tree()
    save_checkpoint(tmp_path, 3, tree)
    dev = jax.devices()[0]
    shardings = jax.tree.map(lambda _: jax.sharding.SingleDeviceSharding(dev), tree)
    restored, step = restore_checkpoint(tmp_path, tree, shardings=shardings)
    assert step == 3
    for leaf in jax.tree.leaves(restored):
        assert leaf.sharding == jax.sharding.SingleDeviceSharding(dev)
