"""Data pipeline determinism/sharding + optimizer behaviour."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # offline CI: vendored shim
    from _hypothesis_compat import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.data import ShardedLoader, SyntheticLMDataset
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, ef_int8_allreduce,
                         linear_warmup_cosine, quantize_int8, dequantize_int8)


class TestData:
    def test_deterministic_batches(self):
        ds = SyntheticLMDataset(vocab_size=512, seq_len=32, seed=7)
        a = ds.batch(3, 4)
        b = ds.batch(3, 4)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        # labels are next-token shifted
        np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])

    def test_different_steps_differ(self):
        ds = SyntheticLMDataset(vocab_size=512, seq_len=32, seed=7)
        assert not np.array_equal(ds.batch(0, 4)["tokens"], ds.batch(1, 4)["tokens"])

    def test_host_sharding_partitions_global_batch(self):
        ds = SyntheticLMDataset(vocab_size=512, seq_len=16, seed=0)
        full = ds.batch(0, 8)
        parts = []
        for host in range(4):
            loader = ShardedLoader(ds, global_batch=8, host_index=host,
                                   num_hosts=4)
            parts.append(next(loader)["tokens"])
            loader.close()
        np.testing.assert_array_equal(np.concatenate(parts), full["tokens"])

    def test_loader_resumes_at_step(self):
        ds = SyntheticLMDataset(vocab_size=512, seq_len=16, seed=0)
        l1 = ShardedLoader(ds, global_batch=4, start_step=5)
        got = next(l1)["tokens"]
        l1.close()
        np.testing.assert_array_equal(got, ds.batch(5, 4)["tokens"])


class TestOptim:
    def _params(self):
        return {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}

    def test_adamw_moves_params_against_gradient(self):
        p = self._params()
        opt = adamw_init(p)
        g = jax.tree.map(jnp.ones_like, p)
        p2, opt2 = adamw_update(g, opt, p, lr=0.1, cfg=AdamWConfig(weight_decay=0.0))
        assert int(opt2["step"]) == 1
        assert float(p2["w"][0, 0]) < 1.0
        assert float(p2["b"][0]) < 0.0

    def test_weight_decay_only_on_matrices(self):
        p = self._params()
        opt = adamw_init(p)
        g = jax.tree.map(jnp.zeros_like, p)
        p2, _ = adamw_update(g, opt, p, lr=0.1, cfg=AdamWConfig(weight_decay=0.5))
        assert float(p2["w"][0, 0]) < 1.0   # decayed
        assert float(p2["b"][0]) == 0.0     # bias untouched

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((10,), 10.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(np.sqrt(1000), rel=1e-5)
        total = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
        assert total == pytest.approx(1.0, rel=1e-4)

    def test_schedule_warmup_then_decay(self):
        lrs = [float(linear_warmup_cosine(jnp.asarray(s), 10, 100, 1.0))
               for s in range(0, 100, 5)]
        assert lrs[1] > lrs[0]
        assert lrs[-1] < max(lrs)
        assert max(lrs) <= 1.0 + 1e-6

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_int8_quant_roundtrip_bound(self, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
        q, scale = quantize_int8(x)
        err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x))
        assert err.max() <= float(scale) / 2 + 1e-6

    def test_ef_allreduce_single_device(self):
        # axis of size 1: sync must equal local grad, error shrinks signal
        import jax.experimental.shard_map as shmap
        from jax.sharding import Mesh, PartitionSpec as P
        mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
        g = jnp.linspace(-1, 1, 32)
        e = jnp.zeros_like(g)

        def f(g, e):
            return ef_int8_allreduce(g, e, "pod")

        out, new_e = jax.jit(shmap.shard_map(
            f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P())))(g, e)
        np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=0.02)
        # error feedback residual bounded by one quant step
        assert float(jnp.abs(new_e).max()) <= float(jnp.abs(g).max()) / 127 + 1e-6
