"""Serving engine (DIANA queues over decode) + fleet grid runtime."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.grid import DianaGridRuntime, PodCapacity, WorkItem
from repro.models import LM
from repro.serving import InferenceRequest, ServingEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_config("gemma2-9b", reduced=True).replace(
        num_layers=2, remat=False, param_dtype="float32",
        compute_dtype="float32")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


def _req(cfg, user, rng, n_new=4, plen=6):
    return InferenceRequest(
        user=user,
        prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
        max_new_tokens=n_new)


class TestServingEngine:
    def test_drains_all_requests(self, engine_setup):
        cfg, lm, params = engine_setup
        rng = np.random.default_rng(0)
        eng = ServingEngine(lm, params, num_slots=2, max_len=32)
        reqs = [_req(cfg, "u", rng) for _ in range(5)]
        for r in reqs:
            eng.submit(r)
        stats = eng.run_until_drained()
        assert stats.served == 5
        assert all(r.done and len(r.generated) == 4 for r in reqs)

    def test_generation_deterministic(self, engine_setup):
        cfg, lm, params = engine_setup
        rng = np.random.default_rng(1)
        prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
        outs = []
        for _ in range(2):
            eng = ServingEngine(lm, params, num_slots=2, max_len=32)
            r = InferenceRequest(user="u", prompt=prompt.copy(), max_new_tokens=4)
            eng.submit(r)
            eng.run_until_drained()
            outs.append(r.generated)
        assert outs[0] == outs[1]

    def test_quota_priority_orders_batches(self, engine_setup):
        """§X: high-quota tenant jumps the low-quota flood."""
        cfg, lm, params = engine_setup
        rng = np.random.default_rng(2)
        eng = ServingEngine(lm, params, num_slots=2, max_len=32,
                            quotas={"hog": 10.0, "vip": 1000.0})
        hogs = [_req(cfg, "hog", rng) for _ in range(6)]
        eng.submit_group(hogs, now=0.0)
        vip = _req(cfg, "vip", rng)
        eng.submit(vip, now=1.0)
        eng.run_until_drained()
        assert vip.first_token_time is not None
        later_hogs = sum(1 for h in hogs if h.first_token_time > vip.first_token_time)
        assert later_hogs >= 3  # vip overtook most of the flood

    def test_prefix_cache_hits(self, engine_setup):
        cfg, lm, params = engine_setup
        rng = np.random.default_rng(3)
        eng = ServingEngine(lm, params, num_slots=2, max_len=32)
        prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
        for _ in range(3):
            eng.submit(InferenceRequest(user="u", prompt=prompt.copy(),
                                        max_new_tokens=2))
        eng.run_until_drained()
        assert eng.stats.prefix_hits >= 2

    def test_truncation_raises_by_default(self, engine_setup):
        """Regression: hitting max_cycles used to return partial stats
        silently; it must now raise (or flag, when asked)."""
        cfg, lm, params = engine_setup
        rng = np.random.default_rng(4)
        eng = ServingEngine(lm, params, num_slots=1, max_len=32)
        for _ in range(4):
            eng.submit(_req(cfg, "u", rng))
        with pytest.raises(RuntimeError, match="truncated"):
            eng.run_until_drained(max_cycles=1)
        assert eng.stats.truncated
        assert eng.stats.cycles == 1
        assert len(eng.queues) > 0          # partial drain really happened

    def test_truncation_flag_mode(self, engine_setup):
        cfg, lm, params = engine_setup
        rng = np.random.default_rng(5)
        eng = ServingEngine(lm, params, num_slots=1, max_len=32)
        for _ in range(4):
            eng.submit(_req(cfg, "u", rng))
        stats = eng.run_until_drained(max_cycles=1, on_truncation="flag")
        assert stats.truncated and stats.cycles == 1
        # a full drain afterwards clears the backlog but keeps the flag
        # as a record that an earlier call truncated
        stats = eng.run_until_drained(on_truncation="flag")
        assert stats.served == 4
        with pytest.raises(ValueError):
            eng.run_until_drained(on_truncation="ignore")


def _pods():
    return [
        PodCapacity(name="p0", chips=256),
        PodCapacity(name="p1", chips=256),
        PodCapacity(name="p2", chips=128, flops=128 * 197e12),
    ]


class TestGridRuntime:
    def test_single_placement_prefers_resident_data(self):
        grid = DianaGridRuntime(_pods())
        item = WorkItem(user="u", arch="a", shape="train_4k",
                        data_bytes=500e9, resident_pod="p1")
        assert grid.schedule(item) == "p1"   # no transfer cost at home

    def test_bulk_split_proportional_to_capacity(self):
        grid = DianaGridRuntime(_pods())
        items = [WorkItem(user="u", arch="a", shape="s") for _ in range(10)]
        placed = grid.schedule_bulk(items, division_factor=3)
        assert sum(len(v) for v in placed.values()) == 10
        assert len(placed["p2"]) <= len(placed["p0"])  # smaller pod, fewer jobs

    def test_straggler_migration(self):
        grid = DianaGridRuntime(_pods(), quotas={"u": 10.0, "v": 1000.0})
        # degrade p2 AND give it a deep multi-user queue
        for i in range(6):
            grid.pods["p2"].enqueue(WorkItem(user="u", arch="a", shape="s"), now=float(i))
        grid.pods["p2"].enqueue(WorkItem(user="v", arch="a", shape="s"), now=6.0)
        grid.set_degraded("p2", 0.3)
        moved = grid.mitigate_stragglers()
        assert moved, "degraded pod should shed queued work"
        assert all(t in ("p0", "p1") for _, t in moved)
        assert all(it.migrated for it, _ in moved)

    def test_pod_failure_reschedules_and_fails_over(self):
        grid = DianaGridRuntime(_pods())
        items = [WorkItem(user="u", arch="a", shape="s") for _ in range(4)]
        for it in items:
            grid.pods["p1"].enqueue(it)
        orphans = grid.pod_failed("p1")
        assert len(orphans) == 4
        assert all(o.pod in ("p0", "p2") for o in orphans)
        # dead pod never selected again
        nxt = grid.schedule(WorkItem(user="u", arch="a", shape="s"))
        assert nxt != "p1"

    def test_elastic_join(self):
        grid = DianaGridRuntime(_pods())
        grid.pod_joined(PodCapacity(name="p3", chips=512, flops=512 * 197e12))
        # heavily load existing pods → new big pod wins placement
        for name in ("p0", "p1", "p2"):
            for i in range(8):
                grid.pods[name].enqueue(WorkItem(user="u", arch="a", shape="s"))
        assert grid.schedule(WorkItem(user="u", arch="a", shape="s")) == "p3"
