"""Sharding-spec derivation + HLO static analyzer unit tests."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch.hlo_analysis import analyze_hlo
from repro.runtime import sharding as shlib
from repro.runtime.pspec import logical_axis_rules, shard, spec_for


def _mesh(shape=(2, 2), axes=("data", "model")):
    devs = np.array(jax.devices()[:1] * int(np.prod(shape))).reshape(shape)
    return Mesh(devs, axes)


class TestParamSpecs:
    def test_attention_weights_megatron(self):
        mesh = _mesh()
        params = {
            "blocks": {
                "attn": {
                    "wq": jax.ShapeDtypeStruct((4, 64, 8, 32), jnp.bfloat16),
                    "wo": jax.ShapeDtypeStruct((4, 8, 32, 64), jnp.bfloat16),
                },
                "mlp": {
                    "w_gate": jax.ShapeDtypeStruct((4, 64, 256), jnp.bfloat16),
                    "w_down": jax.ShapeDtypeStruct((4, 256, 64), jnp.bfloat16),
                },
            },
            "embed": jax.ShapeDtypeStruct((512, 64), jnp.bfloat16),
            "final_norm": jax.ShapeDtypeStruct((64,), jnp.float32),
        }
        specs = shlib.param_specs(mesh, params, zero3=True)
        b = specs["blocks"]
        assert b["attn"]["wq"] == P(None, "data", "model", None)
        assert b["attn"]["wo"] == P(None, "model", None, "data")
        assert b["mlp"]["w_gate"] == P(None, "data", "model")
        assert b["mlp"]["w_down"] == P(None, "model", "data")
        assert specs["embed"] == P("model", "data")
        assert specs["final_norm"] == P(None)

    def test_no_zero3_replicates_input_dims(self):
        mesh = _mesh()
        params = {"blocks": {"mlp": {"w_gate": jax.ShapeDtypeStruct((4, 64, 256), jnp.bfloat16)}}}
        specs = shlib.param_specs(mesh, params, zero3=False)
        assert specs["blocks"]["mlp"]["w_gate"] == P(None, None, "model")

    def test_indivisible_dims_replicate(self):
        mesh = _mesh((2, 16), ("data", "model"))
        params = {"blocks": {"attn": {"wq": jax.ShapeDtypeStruct((4, 64, 10, 32), jnp.bfloat16)}}}
        specs = shlib.param_specs(mesh, params, zero3=True)
        # 10 heads % 16 → replicated, d=64 % 2 → data
        assert specs["blocks"]["attn"]["wq"] == P(None, "data", None, None)

    def test_moe_expert_parallel_2d(self):
        """E divides model×data ⇒ 2-D EP (fully-resident expert weights)."""
        mesh = _mesh()
        params = {"moe_blocks": {"moe": {
            "w_gate": jax.ShapeDtypeStruct((8, 16, 64, 128), jnp.bfloat16)}}}
        specs = shlib.param_specs(mesh, params, zero3=True)
        assert specs["moe_blocks"]["moe"]["w_gate"] == P(None, ("model", "data"), None, None)

    def test_moe_expert_parallel_1d_fallback(self):
        """E % (model·data) ≠ 0 ⇒ 1-D EP over 'model' + ZeRO'd d."""
        mesh = _mesh((2, 3), ("data", "model"))
        params = {"moe_blocks": {"moe": {
            "w_gate": jax.ShapeDtypeStruct((8, 9, 64, 128), jnp.bfloat16)}}}
        specs = shlib.param_specs(mesh, params, zero3=True)
        assert specs["moe_blocks"]["moe"]["w_gate"] == P(None, "model", "data", None)


class TestCacheBatchSpecs:
    def test_cache_seq_over_model_batch_over_data(self):
        mesh = _mesh()
        cache = {"k": jax.ShapeDtypeStruct((8, 16, 1024, 8, 32), jnp.bfloat16)}
        specs = shlib.cache_specs(mesh, cache, batch_size=16)
        assert specs["k"] == P(None, "data", "model", None, None)

    def test_batch_one_replicates(self):
        mesh = _mesh()
        cache = {"k": jax.ShapeDtypeStruct((8, 1, 1024, 8, 32), jnp.bfloat16)}
        specs = shlib.cache_specs(mesh, cache, batch_size=1)
        assert specs["k"] == P(None, None, "model", None, None)

    def test_batch_specs_pod_data(self):
        mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
        batch = {"tokens": jax.ShapeDtypeStruct((8, 128), jnp.int32)}
        specs = shlib.batch_specs(mesh, batch)
        assert specs["tokens"] == P(("pod", "data"), None)


class TestPspec:
    def test_noop_without_context(self):
        x = jnp.ones((4, 4))
        assert shard(x, "batch", None) is x

    def test_spec_resolution_divisibility(self):
        mesh = _mesh((2, 2), ("data", "model"))
        with logical_axis_rules(mesh):
            spec = spec_for(mesh, (4, 10, 8), ("batch", "heads", "ff"))
        # heads=10 % 2 == 0 → sharded; all divisible here
        assert spec == P("data", "model", None) or spec == P("data", None, "model")


SAMPLE_HLO = """
HloModule jit_f, entry_computation_layout={()->f32[]}

%cond (p: (s32[], f32[2,2])) -> pred[] {
  %p = (s32[], f32[2,2]{1,0}) parameter(0)
  %c5 = s32[] constant(5)
  %gte = s32[] get-tuple-element(%p), index=0
  ROOT %lt = pred[] compare(%gte, %c5), direction=LT
}

%body (p2: (s32[], f32[2,2])) -> (s32[], f32[2,2]) {
  %p2 = (s32[], f32[2,2]{1,0}) parameter(0)
  %one = s32[] constant(1)
  %i = s32[] get-tuple-element(%p2), index=0
  %x = f32[2,2]{1,0} get-tuple-element(%p2), index=1
  %ni = s32[] add(%i, %one)
  %y = f32[2,2]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[2,2]{1,0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%cond
  ROOT %t = (s32[], f32[2,2]{1,0}) tuple(%ni, %ar)
}

ENTRY %main () -> f32[] {
  %z = s32[] constant(0)
  %x0 = f32[2,2]{1,0} constant({{1,2},{3,4}})
  %init = (s32[], f32[2,2]{1,0}) tuple(%z, %x0)
  %w = (s32[], f32[2,2]{1,0}) while(%init), condition=%cond, body=%body
  %xf = f32[2,2]{1,0} get-tuple-element(%w), index=1
  ROOT %s = f32[] reduce(%xf, %z), dimensions={0,1}, to_apply=%cond
}
"""


class TestHloAnalyzer:
    def test_while_trip_count_multiplies(self):
        c = analyze_hlo(SAMPLE_HLO)
        # dot: 2·4·2 = 16 flops × 5 trips = 80
        assert c.flops == pytest.approx(80.0)
        # all-reduce: 16 bytes × 2(g−1)/g, g=4 → 24 bytes × 5 trips = 120
        assert c.collective_bytes == pytest.approx(120.0)
        assert c.by_coll["all-reduce"]["count"] == 5

    def test_real_compiled_module(self):
        def f(w, x):
            def body(h, w_):
                return jnp.tanh(h @ w_), None
            h, _ = jax.lax.scan(body, x, w)
            return h.sum()

        lowered = jax.jit(jax.grad(f)).lower(
            jax.ShapeDtypeStruct((3, 16, 16), jnp.float32),
            jax.ShapeDtypeStruct((4, 16), jnp.float32))
        c = analyze_hlo(lowered.compile().as_text())
        # fwd: 3 × 2·4·16·16 = 6144; bwd ≈ 2× more dots
        assert c.flops >= 6144
        assert c.flops <= 6144 * 4
